"""Scheduler behavior (paper §5 Algorithms 1-2 + §6.2.4 comparisons)."""
import pytest

from repro.configs import get_config
from repro.core.cluster_sim import Cluster, Request, hybrid_trace
from repro.core.costmodel import CostModel
from repro.core.scheduler import (GygesScheduler, LeastLoadScheduler,
                                  RoundRobinScheduler, SCHEDULERS,
                                  ScaleDown, ScaleUp, SchedulerConfig)

CFG = get_config("qwen2.5-32b")


class StubView:
    """Minimal InstanceView for policy-only tests (no sim, no jax)."""

    def __init__(self, iid, tp=1, max_tp=4, base_seq=16, used=0.0,
                 reserved=False, long_active=False):
        self.iid = iid
        self.tp = tp
        self.max_tp = max_tp
        self.base_seq = base_seq
        self.reserved = reserved
        self._used = used
        self._long = long_active

    def max_seq_at(self, tp):
        return self.base_seq * tp

    def max_seq(self):
        return self.max_seq_at(self.tp)

    def kv_used_fraction(self):
        return self._used

    def kv_free_tokens(self):
        return int(self.max_seq() * 4 * (1 - self._used))

    def load(self):
        return self._used

    def has_long_request(self):
        return self._long


def test_cost_model_reproduces_table1():
    cm = CostModel(CFG)
    assert [round(cm.instance_tps(tp)) for tp in (1, 2, 4)] == \
        [448, 670, 767]
    assert 3_000 < cm.max_seq(1) < 5_000
    assert 35_000 < cm.max_seq(2) < 48_000
    assert 100_000 < cm.max_seq(4) < 140_000
    # the motivating trade-off: 4xTP1 delivers ~2.33x the TP4 throughput
    ratio = 4 * cm.instance_tps(1) / cm.instance_tps(4)
    assert 2.2 < ratio < 2.5


def test_transform_costs_ordering():
    cm = CostModel(CFG)
    t = {m: cm.transform_time(m) for m in
         ("gyges", "gyges-", "basic", "seesaw")}
    assert t["gyges"] < t["gyges-"] < t["basic"] < t["seesaw"]
    # paper §6.2.3: ~97% cheaper than Seesaw
    assert t["gyges"] / t["seesaw"] < 0.05


def test_gyges_routes_long_to_existing_high_tp():
    """Fig. 13: a new long request must go to the existing TP4 instance,
    not trigger another transformation."""
    c = Cluster(CFG, n_hosts=1, scheduler=GygesScheduler())
    # create one TP4 by submitting a long request
    c.submit(Request(0, 0.0, 50_000, 100), 0.0)
    assert c.n_transforms == 1
    tp4 = [i for i in c.instances if i.tp == 4]
    assert len(tp4) == 1
    # second long request: routed to the same TP4, no new transform
    c.submit(Request(1, 1.0, 40_000, 100), 1.0)
    assert c.n_transforms == 1
    assert len(tp4[0].prefill_q) == 2


def test_unaware_baselines_oscillate_more():
    trace = hybrid_trace(duration=180.0, short_qpm=240, long_qpm=2.0,
                         out_len=200, seed=3)
    n = {}
    for name in ("rr", "llf", "gyges"):
        c = Cluster(CFG, n_hosts=1, scheduler=SCHEDULERS[name]())
        m = c.run(trace, dt=0.5)
        n[name] = m["n_transforms"]
    assert n["gyges"] <= n["llf"]
    assert n["gyges"] <= n["rr"]
    assert n["gyges"] < max(n["rr"], n["llf"])


def test_scale_down_at_low_load():
    """Alg 2: TP>1 instance with no long requests and low load splits."""
    c = Cluster(CFG, n_hosts=1, scheduler=GygesScheduler())
    c.scale_down_dwell = 0.0
    c.submit(Request(0, 0.0, 50_000, 10), 0.0)
    m = c.run([Request(0, 0.0, 50_000, 10)], dt=0.5, drain=120.0)
    # after the long request drains, the cluster is back to 8x TP1
    assert all(i.tp == 1 for i in c.instances)
    assert len(c.instances) == 8


def test_no_scale_down_while_long_in_service():
    sched = GygesScheduler()

    class V:
        tp = 4
        reserved = False
        def kv_used_fraction(self): return 0.05
        def has_long_request(self): return True
        def load(self): return 0.05
        def max_seq(self): return 100_000
        def kv_free_tokens(self): return 90_000

    assert not sched.want_scale_down(V(), any_long_waiting=False)
    v = V()
    v.has_long_request = lambda: False
    assert sched.want_scale_down(v, any_long_waiting=False)
    assert not sched.want_scale_down(v, any_long_waiting=True)


def test_reserved_instances_divert_short_requests():
    sched = GygesScheduler()

    class V:
        def __init__(self, iid, reserved, used):
            self.iid = iid
            self.tp = 1
            self.reserved = reserved
            self._u = used
        def kv_used_fraction(self): return self._u
        def has_long_request(self): return False
        def load(self): return self._u
        def max_seq(self): return 4000
        def kv_free_tokens(self): return int(4000 * (1 - self._u))

    # reserved instance at high utilization is skipped for shorts even
    # though it has the lowest load score after the reserve check
    reserved = V(0, True, 0.93)
    other = V(1, False, 0.94)
    pick = sched.pick([reserved, other], 100, 50)
    assert pick is other


def test_long_threshold_is_the_router_classifier():
    """Satellite: SchedulerConfig.long_threshold is the §5.1 router-side
    long-request classifier — below it a request is short (unless it
    exceeds a concrete instance's ceiling), above it long everywhere."""
    sched = GygesScheduler(SchedulerConfig(long_threshold=100))
    assert not sched.is_long(100)
    assert sched.is_long(101)
    # against a concrete instance, the admission ceiling also classifies
    tiny = StubView(0, tp=1, base_seq=30)
    assert sched.is_long(50, tiny)          # 50 > 30 even though <= 100
    assert not sched.is_long(20, tiny)
    # and the classification drives routing: with a low threshold the
    # same total prefers the existing TP>1 instance; with a high one it
    # prefers TP1 (short-request 4xTP1 preference)
    tp1 = StubView(0, tp=1, base_seq=1000, used=0.01)
    tp4 = StubView(1, tp=4, base_seq=1000, used=0.01)
    low = GygesScheduler(SchedulerConfig(long_threshold=40))
    high = GygesScheduler(SchedulerConfig(long_threshold=4000))
    assert low.pick([tp1, tp4], 50, 10) is tp4
    assert high.pick([tp1, tp4], 50, 10) is tp1


def test_decide_scale_up_returns_declarative_action():
    """Alg 1 lines 14-16: an unplaceable long request yields a ScaleUp
    naming the least-loaded growable instance and the SMALLEST TP degree
    whose ceiling fits; shorts never trigger a transformation."""
    sched = GygesScheduler(SchedulerConfig(long_threshold=16))
    busy = StubView(0, tp=1, max_tp=4, base_seq=16, used=0.6)
    idle = StubView(1, tp=1, max_tp=4, base_seq=16, used=0.1)
    act = sched.decide_scale_up([busy, idle], 24, 6)   # total 30 <= 32
    assert act == ScaleUp(iid=1, tp_to=2, reason=act.reason)
    act = sched.decide_scale_up([busy, idle], 40, 8)   # total 48 <= 64
    assert act.iid == 1 and act.tp_to == 4
    # short request: wait, never transform
    assert sched.decide_scale_up([busy, idle], 4, 4) is None
    # nothing can grow enough
    assert sched.decide_scale_up(
        [StubView(0, tp=4, max_tp=4, base_seq=16)], 100, 10) is None


def test_schedule_parallelism_returns_scale_downs():
    sched = GygesScheduler()
    cold = StubView(0, tp=4, used=0.05)
    hot = StubView(1, tp=4, used=0.9)
    busy_long = StubView(2, tp=4, used=0.05, long_active=True)
    tp1 = StubView(3, tp=1, used=0.0)
    acts = sched.schedule_parallelism([cold, hot, busy_long, tp1],
                                      any_long_waiting=False)
    assert acts == [ScaleDown(iid=0, tp_to=1, reason=acts[0].reason)]
    assert sched.schedule_parallelism([cold], any_long_waiting=True) == []


def test_e2e_method_ordering():
    """Fig. 14 qualitative: Gyges >= PP/SP-style baselines on throughput."""
    from repro.core.cluster_sim import longtail_trace
    # saturating load: PP/SP efficiency difference only shows when the
    # cluster is compute-bound (paper measures at the SLO edge)
    trace = longtail_trace(duration=120.0, qps=8.0, seed=5)
    tps = {}
    for method in ("gyges", "kunserve", "loongserve"):
        c = Cluster(CFG, n_hosts=1, method=method,
                    scheduler=GygesScheduler())
        m = c.run(trace, dt=0.5)
        tps[method] = m["throughput_tps"]
    assert tps["gyges"] > tps["kunserve"]
    assert tps["gyges"] > tps["loongserve"]


from _hypothesis_compat import given, settings, strategies as st


@settings(max_examples=10, deadline=None)
@given(st.lists(st.tuples(st.floats(0, 60), st.sampled_from(
    [500, 1500, 3000, 30_000, 50_000]), st.integers(10, 200)),
    min_size=1, max_size=25), st.sampled_from(["rr", "llf", "gyges"]))
def test_cluster_invariants(reqspec, sched_name):
    """Property: (1) every host always sums to exactly 8 GPUs regardless
    of merges/splits; (2) no request is lost (finished + active + queued
    + waiting == total); (3) tokens generated never exceed demand."""
    reqs = [Request(i, t, ilen, olen)
            for i, (t, ilen, olen) in enumerate(reqspec)]
    c = Cluster(CFG, n_hosts=1, scheduler=SCHEDULERS[sched_name]())
    c.run(reqs, dt=0.5, drain=30.0)
    for host in c.hosts:
        assert sum(i.tp for i in host) == 8, [i.tp for i in host]
    in_system = sum(len(i.active) + len(i.prefill_q)
                    for i in c.instances) + len(c.waiting)
    finished = sum(1 for r in reqs if r.t_finish is not None)
    assert finished + in_system == len(reqs)
    demand = sum(r.out_len for r in reqs)
    assert c.total_tokens <= demand + 1e-6
