"""Scheduler behavior (paper §5 Algorithms 1-2 + §6.2.4 comparisons)."""
import pytest

from repro.configs import get_config
from repro.core.cluster_sim import Cluster, Request, hybrid_trace
from repro.core.costmodel import CostModel
from repro.core.scheduler import (GygesScheduler, LeastLoadScheduler,
                                  PrefillPolicy, RoundRobinScheduler,
                                  SCHEDULERS, ScaleDown, ScaleUp,
                                  SchedulerConfig)

CFG = get_config("qwen2.5-32b")


class StubView:
    """Minimal InstanceView for policy-only tests (no sim, no jax)."""

    def __init__(self, iid, tp=1, max_tp=4, base_seq=16, used=0.0,
                 reserved=False, long_active=False, width=None):
        self.iid = iid
        self.tp = tp
        self.max_tp = max_tp
        self.base_seq = base_seq
        self.reserved = reserved
        self.width = width if width is not None else tp
        self._used = used
        self._long = long_active

    def max_seq_at(self, tp):
        return self.base_seq * tp

    def max_seq(self):
        return self.max_seq_at(self.tp)

    def kv_used_fraction(self):
        return self._used

    def kv_free_tokens(self):
        return int(self.max_seq() * 4 * (1 - self._used))

    def load(self):
        return self._used

    def has_long_request(self):
        return self._long


def test_cost_model_reproduces_table1():
    cm = CostModel(CFG)
    assert [round(cm.instance_tps(tp)) for tp in (1, 2, 4)] == \
        [448, 670, 767]
    assert 3_000 < cm.max_seq(1) < 5_000
    assert 35_000 < cm.max_seq(2) < 48_000
    assert 100_000 < cm.max_seq(4) < 140_000
    # the motivating trade-off: 4xTP1 delivers ~2.33x the TP4 throughput
    ratio = 4 * cm.instance_tps(1) / cm.instance_tps(4)
    assert 2.2 < ratio < 2.5


def test_transform_costs_ordering():
    cm = CostModel(CFG)
    t = {m: cm.transform_time(m) for m in
         ("gyges", "gyges-", "basic", "seesaw")}
    assert t["gyges"] < t["gyges-"] < t["basic"] < t["seesaw"]
    # paper §6.2.3: ~97% cheaper than Seesaw
    assert t["gyges"] / t["seesaw"] < 0.05


def test_gyges_routes_long_to_existing_high_tp():
    """Fig. 13: a new long request must go to the existing TP4 instance,
    not trigger another transformation."""
    c = Cluster(CFG, n_hosts=1, scheduler=GygesScheduler())
    # create one TP4 by submitting a long request
    c.submit(Request(0, 0.0, 50_000, 100), 0.0)
    assert c.n_transforms == 1
    tp4 = [i for i in c.instances if i.tp == 4]
    assert len(tp4) == 1
    # second long request: routed to the same TP4, no new transform
    c.submit(Request(1, 1.0, 40_000, 100), 1.0)
    assert c.n_transforms == 1
    assert len(tp4[0].prefill_q) == 2


def test_unaware_baselines_oscillate_more():
    trace = hybrid_trace(duration=180.0, short_qpm=240, long_qpm=2.0,
                         out_len=200, seed=3)
    n = {}
    for name in ("rr", "llf", "gyges"):
        c = Cluster(CFG, n_hosts=1, scheduler=SCHEDULERS[name]())
        m = c.run(trace, dt=0.5)
        n[name] = m["n_transforms"]
    assert n["gyges"] <= n["llf"]
    assert n["gyges"] <= n["rr"]
    assert n["gyges"] < max(n["rr"], n["llf"])


def test_scale_down_at_low_load():
    """Alg 2: TP>1 instance with no long requests and low load splits."""
    c = Cluster(CFG, n_hosts=1, scheduler=GygesScheduler())
    c.scale_down_dwell = 0.0
    c.submit(Request(0, 0.0, 50_000, 10), 0.0)
    m = c.run([Request(0, 0.0, 50_000, 10)], dt=0.5, drain=120.0)
    # after the long request drains, the cluster is back to 8x TP1
    assert all(i.tp == 1 for i in c.instances)
    assert len(c.instances) == 8


def test_no_scale_down_while_long_in_service():
    sched = GygesScheduler()

    class V:
        tp = 4
        reserved = False
        def kv_used_fraction(self): return 0.05
        def has_long_request(self): return True
        def load(self): return 0.05
        def max_seq(self): return 100_000
        def kv_free_tokens(self): return 90_000

    assert not sched.want_scale_down(V(), any_long_waiting=False)
    v = V()
    v.has_long_request = lambda: False
    assert sched.want_scale_down(v, any_long_waiting=False)
    assert not sched.want_scale_down(v, any_long_waiting=True)


def test_reserved_instances_divert_short_requests():
    sched = GygesScheduler()

    class V:
        def __init__(self, iid, reserved, used):
            self.iid = iid
            self.tp = 1
            self.reserved = reserved
            self._u = used
        def kv_used_fraction(self): return self._u
        def has_long_request(self): return False
        def load(self): return self._u
        def max_seq(self): return 4000
        def kv_free_tokens(self): return int(4000 * (1 - self._u))

    # reserved instance at high utilization is skipped for shorts even
    # though it has the lowest load score after the reserve check
    reserved = V(0, True, 0.93)
    other = V(1, False, 0.94)
    pick = sched.pick([reserved, other], 100, 50)
    assert pick is other


def test_long_threshold_is_the_router_classifier():
    """Satellite: SchedulerConfig.long_threshold is the §5.1 router-side
    long-request classifier — below it a request is short (unless it
    exceeds a concrete instance's ceiling), above it long everywhere."""
    sched = GygesScheduler(SchedulerConfig(long_threshold=100))
    assert not sched.is_long(100)
    assert sched.is_long(101)
    # against a concrete instance, the admission ceiling also classifies
    tiny = StubView(0, tp=1, base_seq=30)
    assert sched.is_long(50, tiny)          # 50 > 30 even though <= 100
    assert not sched.is_long(20, tiny)
    # and the classification drives routing: with a low threshold the
    # same total prefers the existing TP>1 instance; with a high one it
    # prefers TP1 (short-request 4xTP1 preference)
    tp1 = StubView(0, tp=1, base_seq=1000, used=0.01)
    tp4 = StubView(1, tp=4, base_seq=1000, used=0.01)
    low = GygesScheduler(SchedulerConfig(long_threshold=40))
    high = GygesScheduler(SchedulerConfig(long_threshold=4000))
    assert low.pick([tp1, tp4], 50, 10) is tp4
    assert high.pick([tp1, tp4], 50, 10) is tp1


def test_decide_scale_up_returns_declarative_action():
    """Alg 1 lines 14-16: an unplaceable long request yields a ScaleUp
    naming the least-loaded growable instance and the SMALLEST TP degree
    whose ceiling fits; shorts never trigger a transformation."""
    sched = GygesScheduler(SchedulerConfig(long_threshold=16))
    busy = StubView(0, tp=1, max_tp=4, base_seq=16, used=0.6)
    idle = StubView(1, tp=1, max_tp=4, base_seq=16, used=0.1)
    act = sched.decide_scale_up([busy, idle], 24, 6)   # total 30 <= 32
    assert act == ScaleUp(iid=1, tp_to=2, reason=act.reason)
    act = sched.decide_scale_up([busy, idle], 40, 8)   # total 48 <= 64
    assert act.iid == 1 and act.tp_to == 4
    # short request: wait, never transform
    assert sched.decide_scale_up([busy, idle], 4, 4) is None
    # nothing can grow enough
    assert sched.decide_scale_up(
        [StubView(0, tp=4, max_tp=4, base_seq=16)], 100, 10) is None


def test_schedule_parallelism_returns_scale_downs():
    sched = GygesScheduler()
    cold = StubView(0, tp=4, used=0.05)
    hot = StubView(1, tp=4, used=0.9)
    busy_long = StubView(2, tp=4, used=0.05, long_active=True)
    tp1 = StubView(3, tp=1, used=0.0)
    acts = sched.schedule_parallelism([cold, hot, busy_long, tp1],
                                      any_long_waiting=False)
    assert acts == [ScaleDown(iid=0, tp_to=1, reason=acts[0].reason)]
    assert sched.schedule_parallelism([cold], any_long_waiting=True) == []


def test_e2e_method_ordering():
    """Fig. 14 qualitative: Gyges >= PP/SP-style baselines on throughput."""
    from repro.core.cluster_sim import longtail_trace
    # saturating load: PP/SP efficiency difference only shows when the
    # cluster is compute-bound (paper measures at the SLO edge)
    trace = longtail_trace(duration=120.0, qps=8.0, seed=5)
    tps = {}
    for method in ("gyges", "kunserve", "loongserve"):
        c = Cluster(CFG, n_hosts=1, method=method,
                    scheduler=GygesScheduler())
        m = c.run(trace, dt=0.5)
        tps[method] = m["throughput_tps"]
    assert tps["gyges"] > tps["kunserve"]
    assert tps["gyges"] > tps["loongserve"]


from _hypothesis_compat import given, settings, strategies as st


# ---------------------------------------------------------------------------
# PrefillPolicy chunk accounting (hypothesis properties)
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 200_000),                    # prompt_len
       st.integers(1, 9_000),                      # token_budget
       st.sampled_from([8, 16, 64, 128]),          # page_tokens
       st.integers(100, 8_192))                    # long_threshold
def test_chunk_sizes_partition_budget_and_alignment(prompt_len, budget,
                                                    page_tokens,
                                                    long_threshold):
    """For ANY prompt length and budget: the chunks partition the prompt
    exactly; no chunk exceeds the page-aligned effective budget (nor the
    mandatory long-chunking cap); every chunk boundary except the final
    one lands on a page boundary, so a partially-prefilled slot is
    always whole pages + at most one trailing partial page."""
    pol = PrefillPolicy(token_budget=budget, long_threshold=long_threshold)
    chunks = pol.chunk_sizes(prompt_len, page_tokens)
    assert sum(chunks) == prompt_len
    assert all(c > 0 for c in chunks)
    limit = pol.effective_chunk(page_tokens)
    if prompt_len > long_threshold:
        limit = min(limit, max(page_tokens,
                               long_threshold
                               - long_threshold % page_tokens))
    assert all(c <= limit for c in chunks)
    done = 0
    for c in chunks[:-1]:
        done += c
        assert done % page_tokens == 0, (chunks, page_tokens)
    # unbudgeted + short prompt -> single whole-prompt chunk
    whole = PrefillPolicy(token_budget=None, long_threshold=long_threshold)
    if prompt_len <= long_threshold:
        assert whole.chunk_sizes(prompt_len, page_tokens) == [prompt_len]
    else:
        # chunking is mandatory above the long threshold even unbudgeted
        assert len(whole.chunk_sizes(prompt_len, page_tokens)) > 1


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 64),      # active decodes
       st.integers(0, 8),       # max_defer_steps
       st.integers(1, 4096),    # token_budget
       st.integers(1, 100))     # horizon (steps)
def test_decode_priority_starvation_is_bounded(decoding, max_defer,
                                               budget, horizon):
    """Decode-priority may defer prefill while requests are decoding,
    but never beyond max_defer_steps consecutive steps — and every
    non-deferred step grants the full budget.  The sim-side aggregate
    (tokens_over_steps) must equal the live engine's step-by-step sum
    of step_quota, because it IS that sum."""
    pol = PrefillPolicy(token_budget=budget, mode="decode",
                        max_defer_steps=max_defer)
    deferred = 0
    total = 0.0
    worst = 0
    run = 0
    for _ in range(horizon):
        q = pol.step_quota(decoding, deferred)
        if q <= 0:
            deferred += 1
            run += 1
            worst = max(worst, run)
        else:
            assert q == budget
            total += q
            deferred = 0
            run = 0
    assert worst <= max_defer
    got, end_deferred = pol.tokens_over_steps(decoding, horizon)
    assert total == got and end_deferred == deferred
    # the deferral carry makes the guarantee span tick boundaries: the
    # same horizon split into 1-step ticks admits the same tokens
    split_total, d = 0.0, 0
    for _ in range(horizon):
        t, d = pol.tokens_over_steps(decoding, 1, d)
        split_total += t
    assert split_total == total
    # with nothing decoding, prefill is never deferred
    assert pol.step_quota(0, 0) == budget
    # prefill-priority and mixed never defer at all
    for mode in ("prefill", "mixed"):
        p2 = PrefillPolicy(token_budget=budget, mode=mode)
        assert p2.step_quota(decoding, 0) > 0


def test_decide_seed_scale_up_grows_around_the_pick():
    """The shared Fig.-13 policy: in place when the seed's own devices
    reach the ceiling, a merge FORCED to include the seed otherwise,
    None when the seed cannot anchor growth (callers fall through to
    the unrestricted decide path — both planes)."""
    sched = GygesScheduler(SchedulerConfig(long_threshold=16, target_tp=4))
    seed = StubView(0, tp=1, max_tp=4, base_seq=16)
    other = StubView(1, tp=1, max_tp=4, base_seq=16)
    # in place: 48 fits the seed's own 4 devices (64)
    act = sched.decide_seed_scale_up([seed, other], seed, 48)
    assert act.iid == 0 and act.donor_iids == () and act.tp_to == 4
    # beyond the seed's devices: merge that must include the seed
    w1 = StubView(0, tp=1, max_tp=1, base_seq=16)
    w2 = StubView(1, tp=1, max_tp=1, base_seq=16)
    w1.width = w2.width = 4
    act = sched.decide_seed_scale_up([w1, w2], w1, 96)
    assert act is not None and 0 in {act.iid, *act.donor_iids}
    # an already-scaled seed cannot anchor growth -> None
    up = StubView(2, tp=4, max_tp=4, base_seq=16)
    assert sched.decide_seed_scale_up([up, other], up, 1000) is None


@settings(max_examples=10, deadline=None)
@given(st.lists(st.tuples(st.floats(0, 60), st.sampled_from(
    [500, 1500, 3000, 30_000, 50_000]), st.integers(10, 200)),
    min_size=1, max_size=25), st.sampled_from(["rr", "llf", "gyges"]))
def test_cluster_invariants(reqspec, sched_name):
    """Property: (1) every host always sums to exactly 8 GPUs regardless
    of merges/splits; (2) no request is lost (finished + active + queued
    + waiting == total); (3) tokens generated never exceed demand."""
    reqs = [Request(i, t, ilen, olen)
            for i, (t, ilen, olen) in enumerate(reqspec)]
    c = Cluster(CFG, n_hosts=1, scheduler=SCHEDULERS[sched_name]())
    c.run(reqs, dt=0.5, drain=30.0)
    for host in c.hosts:
        assert sum(i.tp for i in host) == 8, [i.tp for i in host]
    in_system = sum(len(i.active) + len(i.prefill_q)
                    for i in c.instances) + len(c.waiting)
    finished = sum(1 for r in reqs if r.t_finish is not None)
    assert finished + in_system == len(reqs)
    demand = sum(r.out_len for r in reqs)
    assert c.total_tokens <= demand + 1e-6


def test_pressure_is_opt_in_and_narrows_merges():
    """The arrival-pressure estimator is strictly opt-in: without
    ``attach_pressure`` every decision is the pre-event-loop one
    (``pressure_high`` is vacuously False, ``decide_merge`` builds to
    ``target_tp``).  With it, LOW predicted pressure narrows the merge
    to the cheapest adequate width (2), and HIGH pressure restores the
    full-width build."""
    from repro.core.events import ArrivalPressure

    def views():
        return [StubView(i, tp=1, base_seq=16, used=0.0)
                for i in range(8)]

    # total 24 tokens: fits a width-2 merge (ceiling 32), not TP1 (16)
    total = 24
    blind = GygesScheduler(SchedulerConfig(long_threshold=16,
                                           target_tp=4,
                                           transform_cost_s=5.0))
    assert blind.pressure is None and not blind.pressure_high()
    act = blind.decide_merge(views(), total)
    assert isinstance(act, ScaleUp) and act.tp_to == 4
    assert len(act.donor_iids) == 3

    aware = GygesScheduler(SchedulerConfig(long_threshold=16,
                                           target_tp=4,
                                           transform_cost_s=5.0))
    aware.attach_pressure(ArrivalPressure(tau_s=30.0))
    # no arrivals observed -> low pressure -> narrowest adequate merge
    act = aware.decide_merge(views(), total)
    assert isinstance(act, ScaleUp) and act.tp_to == 2
    assert len(act.donor_iids) == 1
    # a burst of observed longs raises the expected-longs estimate over
    # the 2x-transform-cost horizon -> full-width merge again
    for _ in range(20):
        aware.observe_arrival(0.0, total_tokens=50_000)
    assert aware.pressure_high()
    act = aware.decide_merge(views(), total)
    assert isinstance(act, ScaleUp) and act.tp_to == 4


# -- capacity ladder: spill < partial merge < full merge ----------------


def test_donor_loanable_admissibility():
    """The relaxed merge-admissibility predicate: a donor may join a
    (partial) merge iff it can shed >= 1 device and keep serving —
    replacing the old hard requirement of a whole idle TP1 engine."""
    sch = GygesScheduler(SchedulerConfig(long_threshold=16))
    # single-device engines have nothing to spare
    assert sch.donor_loanable(StubView(0, tp=1, width=1)) == 0
    # an idle width-4 donor keeps 1 device, loans 3
    assert sch.donor_loanable(StubView(1, tp=1, width=4)) == 3
    # 60% full: keep = ceil(0.6 * 4) = 3, loan 1
    assert sch.donor_loanable(StubView(2, tp=1, width=4, used=0.6)) == 1
    # full: nothing loanable
    assert sch.donor_loanable(StubView(3, tp=1, width=4, used=1.0)) == 0
    # a long request pins the donor's whole ceiling
    assert sch.donor_loanable(
        StubView(4, tp=1, width=4, long_active=True)) == 0


def test_ladder_is_opt_in():
    """Defaults keep legacy behavior byte-identical: without the
    ``spill`` / ``partial_merge`` flags the ladder rungs return None
    and ``decide_capacity`` degrades to plain ``decide_merge``."""
    sch = GygesScheduler(SchedulerConfig(long_threshold=16, target_tp=4))
    views = [StubView(i, tp=1, width=2, base_seq=16) for i in range(4)]
    assert sch.decide_spill(views, 40) is None
    assert sch.decide_partial_merge(views, 56) is None
    act = sch.decide_capacity(views, 56)
    assert isinstance(act, ScaleUp) and not act.donor_devices


def test_decide_partial_merge_geometry():
    """Width-2 engines, pool 8: a 56-token request widens one target to
    4 with two donors loaning one device each — every donor keeps a
    device and keeps serving."""
    sch = GygesScheduler(SchedulerConfig(long_threshold=16, target_tp=4,
                                         partial_merge=True))
    views = [StubView(i, tp=1, width=2, base_seq=16) for i in range(4)]
    act = sch.decide_partial_merge(views, 56)
    assert isinstance(act, ScaleUp)
    assert act.tp_to == 4
    assert act.donor_iids == (1, 2)       # idlest-first, iid tie-break
    assert act.donor_devices == (1, 1)    # each keeps one device
    # a busy donor is skipped in favor of idler ones
    views[1]._used = 0.9
    act = sch.decide_partial_merge(views, 56)
    assert act.donor_iids == (2, 3)


def test_decide_spill_bounds_and_host_choice():
    """Spill serves only bounded overflow (<= spill_slack * ceiling)
    and needs a host with whole free slots for the overflow."""
    sch = GygesScheduler(SchedulerConfig(long_threshold=16, target_tp=4,
                                         spill=True, spill_slack=2.0))
    views = [StubView(i, tp=1, width=2, base_seq=16) for i in range(4)]
    act = sch.decide_spill(views, 40)     # overflow 24 <= 32
    assert act is not None
    assert act.iid == 0 and act.host_iid == 1 and act.tokens == 24
    assert sch.decide_spill(views, 16) is None       # fits locally
    assert sch.decide_spill(views, 49) is None       # overflow 33 > 32
    # hosts without the free slots are skipped
    for v in views[1:]:
        v._used = 1.0
    assert sch.decide_spill(views, 40) is None


def test_decide_capacity_orders_the_rungs():
    """When several rungs can serve the request the ladder takes the
    cheapest: spill < partial merge < full merge (rung index without a
    cost model; Table-1 modeled seconds with one attached)."""
    sch = GygesScheduler(SchedulerConfig(long_threshold=16, target_tp=4,
                                         spill=True, partial_merge=True,
                                         spill_slack=2.0))
    views = [StubView(i, tp=1, width=2, base_seq=16) for i in range(4)]
    from repro.core.scheduler import Spill
    assert isinstance(sch.decide_capacity(views, 40), Spill)
    act = sch.decide_capacity(views, 56)  # overflow 40 > slack: no spill
    assert isinstance(act, ScaleUp) and act.donor_devices == (1, 1)
    # with a Table-1 cost model attached the ordering is by modeled
    # seconds, and a small spill still beats any transform
    sch.attach_cost(CostModel(CFG))
    assert isinstance(sch.decide_capacity(views, 40), Spill)
