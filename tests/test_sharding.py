"""Sharding-layer unit tests: pspec trees, HLO collective parser, blocked
MoE dispatch equivalence, shard hints."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core.padding import make_plan
from repro.launch import sharding as SH
from repro.launch import specs as SP
from repro.launch.hlo_analysis import collective_bytes
from repro.models import model as M
from repro.models import shardhints


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_pspecs_divisible(arch):
    """Every sharded dim must divide the 16-wide model axis (the padding
    plan's whole job); FSDP adds data-axis shards only when divisible."""
    cfg = get_config(arch)
    plan = make_plan(cfg, 16, mode="lane")
    sds = SP.param_specs(cfg, plan)
    ps = SH.param_pspecs(sds, cfg, plan, fsdp=True, data_size=16)
    leaves_s, _ = jax.tree.flatten(sds)
    leaves_p = jax.tree.leaves(ps, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves_s) == len(leaves_p)
    axis = {"model": 16, "data": 16}
    n_sharded = 0
    for s, spec in zip(leaves_s, leaves_p):
        for dim, names in enumerate(spec):
            if names is None:
                continue
            names = names if isinstance(names, tuple) else (names,)
            total = 1
            for nm in names:
                total *= axis[nm]
            assert s.shape[dim] % total == 0, (arch, s.shape, spec)
            n_sharded += 1
    assert n_sharded > 0


def test_cache_pspecs_decode_modes():
    cfg = get_config("llama3-8b")
    plan = make_plan(cfg, 16)
    from repro.configs import SHAPES
    c_sds = SP.cache_specs(cfg, plan, SHAPES["decode_32k"])
    mesh = type("M", (), {"shape": {"data": 16, "model": 16}})()
    ps = SH.cache_pspecs(c_sds, mesh, ("data",), 128, "tp")
    pool_spec = ps["groups"][0].pool
    assert pool_spec[1] in (("data",), "data")
    assert pool_spec[2] == "model"


def test_hlo_collective_parser():
    txt = """
  %ag = bf16[16,128,256]{2,1,0} all-gather(%x), replica_groups={}
  %ar.1 = f32[1024]{0} all-reduce(%y), to_apply=%sum
  %a2a = bf16[8,64]{1,0} all-to-all(%z)
  %a2at = (f32[4,8]{1,0}, f32[4,8]{1,0}) all-to-all(%u, %v), dimensions={0}
  %cp = s32[4]{0} collective-permute(%w)
  %agd = bf16[16,128,256]{2,1,0} all-gather-done(%ag)
  %notacoll = f32[2,2]{1,0} add(%a, %b)
"""
    d = collective_bytes(txt)
    assert d["all-gather"] == 16 * 128 * 256 * 2
    assert d["all-reduce"] == 1024 * 4
    # tuple-shaped all-to-all results are fully counted
    assert d["all-to-all"] == 8 * 64 * 2 + 2 * 4 * 8 * 4
    assert d["collective-permute"] == 4 * 4
    assert d["count"] == 5  # -done not double counted


def test_blocked_moe_dispatch_equals_unblocked(rng):
    """Hierarchical (block-local) dispatch must equal global dispatch when
    capacity is ample (no drops) — §Perf P2 iteration 4 correctness."""
    cfg = get_config("granite-moe-3b-a800m").reduced()  # cf = 8.0
    plan = make_plan(cfg, 2)
    params = M.init_params(rng, cfg, plan)
    batch = {"tokens": jax.random.randint(rng, (4, 32), 0, cfg.vocab_size)}
    a, _ = M.forward_train(params, cfg, plan, batch)
    with shardhints.hints(moe_blocks=4):
        b, _ = M.forward_train(params, cfg, plan, batch)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_shardhints_scoping():
    assert shardhints.get("zzz") is None
    with shardhints.hints(zzz=5):
        assert shardhints.get("zzz") == 5
        with shardhints.hints(yyy=1):
            assert shardhints.get("zzz") == 5
    assert shardhints.get("zzz") is None
    x = jnp.ones((4,))
    assert shardhints.constrain(x, "nope") is x


def test_long_context_variant():
    from repro.launch.specs import long_context_variant, supports_shape
    from repro.configs import SHAPES
    lc = long_context_variant(get_config("llama3-8b"))
    assert lc.sub_quadratic and lc.window == 4096
    # native sub-quadratic archs unchanged
    rg = get_config("recurrentgemma-9b")
    assert long_context_variant(rg) is rg
    ok, why = supports_shape(get_config("whisper-tiny"), SHAPES["long_500k"])
    assert not ok and "skip" in why.lower() or not ok
