"""Sim/live differential parity harness (the ISSUE-4 satellite).

The architectural claim docs/architecture.md makes — ONE policy object
drives both planes — is tested DIFFERENTIALLY here: the same trace is
replayed through the simulator (``core.cluster_sim.Cluster``) and the
live plane (``serving.cluster.ClusterEngine`` on fake devices) under an
identical ``PrefillPolicy`` + ``SchedulerConfig``, and the DECISIONS
must match plane-for-plane:

* routing picks — ``placements`` (rid -> instance iid) identical;
* parallelism actions — the executed ScaleUp/ScaleDown sequence
  identical (same targets, same TP degrees, same merge donors);
* metrics — the exact METRIC_KEYS schema from both.

The replay protocol drains the cluster between submissions so every
decision happens against equivalent instance views (live engines report
byte-level KV occupancy, the sim reports modeled occupancy — equal only
at idle), which is exactly what makes this a decision-level harness:
any drift in the shared policy surface (capacity contract, long
classifier, donor selection, seed scale-up, tie-breaks, instance
identity across merge/split) shows up as a plane diff.

The live half needs >= 8 devices.  In CI the PR lane exports
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the fast
cases run in-process; elsewhere (e.g. a bare ``pytest``) the harness
transparently re-executes itself in a subprocess with the flag set.

Geometry: 8 single-device engines (so every scale-up is a MERGE in both
planes — sim instances can never grow in place), per-device quantum 16
tokens, matched via ``Cluster(seq_quantum=..., max_batch=...)``.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: trace replayed under every scheduler: (rid, prompt_len, out_len).
#: shorts fit TP1 (total <= 16); the long (total 48) needs a width-4
#: merge; the final shorts run after the split restored 8x TP1
TRACE = [(0, 10, 4), (1, 12, 4), (2, 8, 4),
         (3, 40, 8),                       # the merge trigger
         (4, 10, 4), (5, 6, 4)]

DRIVER = """
    import itertools, json
    import jax, numpy as np

    import dataclasses
    from repro.configs import get_config
    from repro.core.cluster_sim import Cluster, SimInstance
    from repro.core.scheduler import (PrefillPolicy, SCHEDULERS,
                                      ScaleUp, SchedulerConfig)
    from repro.serving.cluster import ClusterEngine
    from repro.serving.metrics import METRIC_KEYS
    from repro.serving.request import Request, ServeRequest

    TRACE = {trace}
    SCHED = {sched!r}

    Q = 16                      # per-device admission quantum (tokens)
    POLICY = PrefillPolicy(token_budget=16, mode="mixed",
                           long_threshold=Q, order="sjf")
    mk_sched = lambda: SCHEDULERS[SCHED](SchedulerConfig(
        long_threshold=Q, target_tp=4))

    def act_key(a):
        return (type(a).__name__, a.iid, a.tp_to,
                tuple(sorted(getattr(a, "donor_iids", ()) or ())))

    # ---- live plane: 8 single-device engines ----------------------
    cfg = dataclasses.replace(get_config("llama3-8b").reduced(),
                              dtype="float32")
    devs = jax.devices()
    assert len(devs) >= 8, len(devs)
    rng = np.random.default_rng(0)
    prompts = {{rid: rng.integers(0, cfg.vocab_size, size=n).tolist()
               for rid, n, _ in TRACE}}
    live = ClusterEngine(cfg, devs[:8], n_instances=8, max_batch=2,
                         max_seq=Q, page_tokens=Q, dwell_steps=4,
                         scheduler=mk_sched(), prefill_policy=POLICY)
    for rid, n, out in TRACE:
        live.submit(ServeRequest(rid=rid, prompt=list(prompts[rid]),
                                 max_new_tokens=out))
        live.run(max_steps=8000)    # drain + Alg-2 quiet window
        assert all(e.tp == 1 and not e.parked for e in live.engines)
    live_metrics = live.run(max_steps=8000)

    # ---- simulated plane: matched geometry ------------------------
    sim = Cluster(cfg, n_hosts=1, gpus_per_host=8,
                  scheduler=mk_sched(), target_tp=4,
                  prefill_policy=POLICY, seq_quantum=Q, max_batch=2)
    sim.scale_down_dwell = 5.0
    now = 0.0
    dt = 0.25
    for rid, n, out in TRACE:
        sim.submit(Request(rid, now, n, out), now)
        for _ in range(20000):
            sum(i.tick(now, dt) for i in sim.instances)
            eligible = [i for i in sim.instances if i.tp > 1 and
                        now > i.transform_until + sim.scale_down_dwell]
            by_iid = {{i.iid: i for i in eligible}}
            for act in sim.scheduler.schedule_parallelism(
                    eligible, False):
                sim.execute_scale_down(by_iid[act.iid], now)
            now += dt
            done = all(r.finished for r in sim.all_requests
                       if r.rid == rid) if sim.all_requests else True
            if done and all(i.tp == 1 for i in sim.instances) \
                    and not sim.waiting:
                break
        else:
            raise RuntimeError(f"sim did not drain request {{rid}}")
    sim_metrics = sim.metrics(now)

    print("RESULT " + json.dumps({{
        "scheduler": SCHED,
        "live_placements": {{str(k): v
                            for k, v in live.placements.items()}},
        "sim_placements": {{str(k): v
                           for k, v in sim.placements.items()}},
        "live_actions": [act_key(a) for a in live.actions],
        "sim_actions": [act_key(a) for a in sim.actions],
        "live_keys": list(live_metrics), "sim_keys": list(sim_metrics),
        "metric_keys": list(METRIC_KEYS),
        "live_merges": sum(1 for a in live.actions
                           if isinstance(a, ScaleUp) and a.donor_iids),
    }}))
"""


def _run_driver(body: str, tag: str) -> dict:
    """Run a dual-plane driver body, in-process when the session
    already has >= 8 devices (the CI configuration), else in a
    subprocess that forces 8 fake host devices."""
    use_subprocess = True
    if "xla_force_host_platform_device_count=8" in os.environ.get(
            "XLA_FLAGS", ""):
        use_subprocess = False
    if use_subprocess:
        env = dict(os.environ,
                   XLA_FLAGS="--xla_force_host_platform_device_count=8",
                   PYTHONPATH=os.pathsep.join(
                       [os.path.join(REPO, "src"), REPO]))
        out = subprocess.run([sys.executable, "-c", body],
                             capture_output=True, text=True, env=env,
                             timeout=900)
        assert out.returncode == 0, (
            f"STDOUT:\n{out.stdout}\nERR:\n{out.stderr}")
        stdout = out.stdout
    else:
        import contextlib
        import io
        buf = io.StringIO()
        with contextlib.redirect_stdout(buf):
            exec(compile(body, f"<parity:{tag}>", "exec"), {})
        stdout = buf.getvalue()
    line = next(ln for ln in stdout.splitlines()
                if ln.startswith("RESULT "))
    return json.loads(line[len("RESULT "):])


def _drive(sched: str) -> dict:
    body = textwrap.dedent(DRIVER).format(trace=TRACE, sched=sched)
    return _run_driver(body, sched)


@pytest.mark.parametrize("sched", ["gyges", "llf", "rr"])
def test_decision_parity_sim_vs_live(sched):
    """Same trace, same PrefillPolicy, same SchedulerConfig -> the two
    planes route every request to the same instance, execute the same
    ScaleUp/ScaleDown sequence (same merge targets and donors), and
    report the same metrics schema."""
    r = _drive(sched)
    assert r["live_placements"] == r["sim_placements"], (
        sched, r["live_placements"], r["sim_placements"])
    assert r["live_actions"] == r["sim_actions"], (
        sched, r["live_actions"], r["sim_actions"])
    # the trace's long request really forced a cross-instance merge
    assert r["live_merges"] >= 1, r["live_actions"]
    assert r["live_keys"] == r["sim_keys"] == r["metric_keys"]


#: capacity-ladder geometry: 4 width-2 engines (pool 8), quantum 16.
#: In-place growth covers totals <= 32, so with spill_slack=2.0 the
#: spill rung owns totals 33-48 and the partial-merge rung 49-64:
#:   r1 (total 40) -> KV spill, guest 0 hosting on 1, NO transform;
#:   r2 (total 56) -> partial merge: target 0 widens to 4 on one
#:                    device from each of donors 1 and 2 — who keep
#:                    serving at width 1 (nobody parks, nobody drains)
LADDER_TRACE = [(0, 10, 4), (1, 24, 16), (2, 40, 16), (3, 10, 4)]

LADDER_DRIVER = """
    import dataclasses, json
    import jax, numpy as np

    from repro.configs import get_config
    from repro.core.cluster_sim import Cluster
    from repro.core.scheduler import (GygesScheduler, PrefillPolicy,
                                      SchedulerConfig, ScaleUp, Spill)
    from repro.serving.cluster import ClusterEngine
    from repro.serving.metrics import METRIC_KEYS
    from repro.serving.request import Request, ServeRequest

    TRACE = {trace}
    Q = 16
    POLICY = PrefillPolicy(token_budget=16, mode="mixed",
                           long_threshold=Q, order="sjf")
    mk_sched = lambda: GygesScheduler(SchedulerConfig(
        long_threshold=Q, target_tp=4, spill=True, partial_merge=True,
        spill_slack=2.0))

    def act_key(a):
        return (type(a).__name__, a.iid, getattr(a, "tp_to", None),
                tuple(sorted(getattr(a, "donor_iids", ()) or ())),
                tuple(getattr(a, "donor_devices", ()) or ()),
                getattr(a, "host_iid", None))

    # ---- live plane: 4 width-2 engines ----------------------------
    cfg = dataclasses.replace(get_config("llama3-8b").reduced(),
                              dtype="float32")
    devs = jax.devices()
    assert len(devs) >= 8, len(devs)
    rng = np.random.default_rng(0)
    prompts = {{rid: rng.integers(0, cfg.vocab_size, size=n).tolist()
               for rid, n, _ in TRACE}}
    live = ClusterEngine(cfg, devs[:8], n_instances=4, max_batch=2,
                         max_seq=2 * Q, page_tokens=Q, dwell_steps=4,
                         scheduler=mk_sched(), prefill_policy=POLICY)
    # width-2 engines construct at tp=2; the ladder serves shorts at
    # tp=1, so warm every engine down (a same-degree contract as the
    # sim's widths= construction; direct engine calls, no actions)
    for e in live.engines:
        e.transform(1)
    live.run(max_steps=4000)
    assert not live.actions and live.n_transforms == 0
    for rid, n, out in TRACE:
        live.submit(ServeRequest(rid=rid, prompt=list(prompts[rid]),
                                 max_new_tokens=out))
        live.run(max_steps=8000)    # drain + Alg-2 quiet window
        assert all(e.tp == 1 and not e.parked for e in live.engines)
        assert not live.partition.spills()
    live_metrics = live.metrics()

    # ---- simulated plane: matched geometry ------------------------
    sim = Cluster(cfg, n_hosts=1, gpus_per_host=8, scheduler=mk_sched(),
                  target_tp=4, prefill_policy=POLICY, seq_quantum=Q,
                  max_batch=2, widths=[2, 2, 2, 2], page_tokens=Q)
    sim.scale_down_dwell = 5.0
    now = 0.0
    dt = 0.25
    for rid, n, out in TRACE:
        sim.submit(Request(rid, now, n, out), now)
        for _ in range(20000):
            sim.advance(now, dt)
            now += dt
            done = all(r.tokens_done >= r.out_len
                       for r in sim._req_by_rid.values())
            if done and all(i.tp == 1 for i in sim.instances) \
                    and not sim.waiting and not sim.partition.spills():
                break
        else:
            raise RuntimeError(f"sim did not drain request {{rid}}")
        sim.partition.check_invariants()
    sim_metrics = sim.metrics(now)
    live.partition.check_invariants()

    print("RESULT " + json.dumps({{
        "live_placements": {{str(k): v
                            for k, v in live.placements.items()}},
        "sim_placements": {{str(k): v
                           for k, v in sim.placements.items()}},
        "live_actions": [act_key(a) for a in live.actions],
        "sim_actions": [act_key(a) for a in sim.actions],
        "live_keys": list(live_metrics), "sim_keys": list(sim_metrics),
        "metric_keys": list(METRIC_KEYS),
        "live_spills": sum(1 for a in live.actions
                           if isinstance(a, Spill)),
        "live_partials": sum(1 for a in live.actions
                             if isinstance(a, ScaleUp)
                             and a.donor_devices),
        "live_spill_pages": live_metrics["spill_pages"],
        "sim_spill_pages": sim_metrics["spill_pages"],
        "live_partial_merges": live_metrics["partial_merges"],
        "sim_partial_merges": sim_metrics["partial_merges"],
    }}))
"""


def test_ladder_decision_parity_partial_merge_and_spill():
    """The capacity-ladder tentpole, differentially: a trace whose
    longs trigger >= 1 KV spill and >= 1 partial merge replays through
    both planes with identical routing, an identical action sequence
    (same spill guest/host, same partial-merge target, donors AND
    per-donor device counts), and identical spill/partial counters."""
    body = textwrap.dedent(LADDER_DRIVER).format(trace=LADDER_TRACE)
    r = _run_driver(body, "ladder")
    assert r["live_placements"] == r["sim_placements"], (
        r["live_placements"], r["sim_placements"])
    assert r["live_actions"] == r["sim_actions"], (
        r["live_actions"], r["sim_actions"])
    assert r["live_spills"] >= 1, r["live_actions"]
    assert r["live_partials"] >= 1, r["live_actions"]
    assert r["live_spill_pages"] == r["sim_spill_pages"] > 0
    assert r["live_partial_merges"] == r["sim_partial_merges"] >= 1
    assert r["live_keys"] == r["sim_keys"] == r["metric_keys"]


#: the timed case delegates to the SAME dual-replay driver the CI
#: ``bench_e2e --replay-smoke`` lane runs at 1000+ requests — one code
#: path, two scales
TIMED_DRIVER = """
    import json, sys
    sys.path.insert(0, {repo!r})
    from benchmarks.bench_e2e import timed_dual_replay
    r = timed_dual_replay(n_bursts=8)
    print("RESULT " + json.dumps({{
        "n_requests": r["n_requests"],
        "placements_equal": r["placements_equal"],
        "actions_equal": r["actions_equal"],
        "live_merges": r["live_merges"],
        "live_goodput": r["live"]["goodput_slo"],
        "sim_goodput": r["sim"]["goodput_slo"],
        "live_finished": r["live"]["finished"],
        "sim_finished": r["sim"]["finished"],
    }}))
"""


def test_timed_trace_decision_parity():
    """The tentpole invariant under the EVENT clock: a bursty timed
    trace (arrival timestamps, SLOs, merge-forcing longs) replayed
    through both planes on one virtual clock yields identical routing
    and identical parallelism-action sequences, and both planes report
    positive goodput on the virtual time axis."""
    body = textwrap.dedent(TIMED_DRIVER).format(repo=REPO)
    r = _run_driver(body, "timed")
    assert r["placements_equal"], "sim/live routing diverged under time"
    assert r["actions_equal"], "sim/live action sequences diverged"
    assert r["live_merges"] >= 1, "timed trace forced no live merge"
    assert r["live_finished"] == r["sim_finished"] == r["n_requests"]
    assert r["live_goodput"] > 0.0 and r["sim_goodput"] > 0.0, r


#: elastic-SP geometry: ONE engine owning all 4 devices.  The long
#: request (total 64 = the full 4xQ16 pool) forces an in-place ScaleUp
#: to TP4; its 24-token decode tail then outlives the modeled transform
#: window, so the ``layouts=True`` scan sees a long-dominated TP4
#: instance and issues the same-degree re-factorization to SP2xTP2
#: (layout_decode_tps: 1264 long-context tok/s vs TP4's 767) in BOTH
#: planes before the usual split back to TP1
LAYOUT_TRACE = [(0, 4, 8), (1, 4, 8), (2, 40, 24), (3, 4, 8)]

LAYOUT_DRIVER = """
    import dataclasses, json
    import jax, numpy as np

    from repro.configs import get_config
    from repro.core.cluster_sim import Cluster
    from repro.core.scheduler import (GygesScheduler, PrefillPolicy,
                                      SchedulerConfig)
    from repro.serving.cluster import ClusterEngine
    from repro.serving.metrics import METRIC_KEYS
    from repro.serving.request import Request, ServeRequest

    TRACE = {trace}
    Q = 16
    POLICY = PrefillPolicy(token_budget=Q, mode="mixed",
                           long_threshold=Q, order="sjf")
    mk_sched = lambda: GygesScheduler(SchedulerConfig(
        long_threshold=Q, target_tp=4, partial_merge=True,
        layouts=True))

    def act_key(a):
        return (type(a).__name__, a.iid, getattr(a, "tp_to", None),
                tuple(sorted(getattr(a, "donor_iids", ()) or ())),
                str(getattr(a, "layout", None)))

    # ---- live plane: one 4-device engine --------------------------
    cfg = dataclasses.replace(get_config("llama3-8b").reduced(),
                              dtype="float32")
    devs = jax.devices()
    assert len(devs) >= 4, len(devs)
    rng = np.random.default_rng(0)
    prompts = {{rid: rng.integers(0, cfg.vocab_size, size=n).tolist()
               for rid, n, _ in TRACE}}
    live = ClusterEngine(cfg, devs[:4], n_instances=1, max_batch=4,
                         max_seq=4 * Q, page_tokens=Q, dwell_steps=4,
                         scheduler=mk_sched(), prefill_policy=POLICY)
    for rid, n, out in TRACE:
        live.submit(ServeRequest(rid=rid, prompt=list(prompts[rid]),
                                 max_new_tokens=out))
        live.run(max_steps=8000)    # drain + Alg-2 quiet window
        assert all(e.tp == 1 and not e.parked
                   for e in live.engines), rid
    live_metrics = live.run(max_steps=8000)

    # ---- simulated plane: matched geometry ------------------------
    sim = Cluster(cfg, n_hosts=1, gpus_per_host=4, widths=[4],
                  scheduler=mk_sched(), target_tp=4,
                  prefill_policy=POLICY, seq_quantum=Q, max_batch=4)
    sim.scale_down_dwell = 0.0
    now, dt = 0.0, 0.25
    for rid, n, out in TRACE:
        sim.submit(Request(rid, now, n, out), now)
        for _ in range(20000):
            sim.advance(now, dt)
            now += dt
            done = all(r.tokens_done >= r.out_len
                       for r in sim._req_by_rid.values())
            if done and all(i.tp == 1 for i in sim.instances) \\
                    and not sim.waiting:
                break
        else:
            raise RuntimeError(f"sim did not drain request {{rid}}")
    sim_metrics = sim.metrics(now)

    print("RESULT " + json.dumps({{
        "live_placements": {{str(k): v
                            for k, v in live.placements.items()}},
        "sim_placements": {{str(k): v
                           for k, v in sim.placements.items()}},
        "live_actions": [act_key(a) for a in live.actions],
        "sim_actions": [act_key(a) for a in sim.actions],
        "live_keys": list(live_metrics), "sim_keys": list(sim_metrics),
        "metric_keys": list(METRIC_KEYS),
        "live_layout_acts": sum(
            1 for a in live.actions
            if "SP" in str(getattr(a, "layout", ""))),
    }}))
"""


def test_layout_decision_parity_sim_vs_live():
    """The elastic-SP scan, differentially: a long-decode trace where
    ``decide_layout`` re-factorizes the TP4 instance to SP2xTP2 in
    flight must produce that same-degree layout action — and everything
    around it — decision-for-decision in both planes."""
    body = textwrap.dedent(LAYOUT_DRIVER).format(trace=LAYOUT_TRACE)
    r = _run_driver(body, "layout")
    assert r["live_placements"] == r["sim_placements"], (
        r["live_placements"], r["sim_placements"])
    assert r["live_actions"] == r["sim_actions"], (
        r["live_actions"], r["sim_actions"])
    # the long really triggered the same-degree re-factorization
    assert r["live_layout_acts"] >= 1, r["live_actions"]
    assert any(a[4] == "SP2xTP2" for a in r["live_actions"]), (
        r["live_actions"])
    assert r["live_keys"] == r["sim_keys"] == r["metric_keys"]
