"""End-to-end behaviour tests for the paper's system: engine + scheduler
+ transformation working together (single-device CPU path)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.cluster_sim import Cluster, Request
from repro.core.scheduler import GygesScheduler
from repro.core.transform_engine import (scale_down_schedule,
                                         scale_up_schedule, schedule_cost)
from repro.core.kv_transform import LinkModel, account_scale_up
from repro.core.padding import make_plan
from repro.serving import Engine, ServeRequest


def test_schedules_follow_paper_rules():
    up = scale_up_schedule(8, layers_per_step=2)
    # MLP-first: every mlp step precedes every kv step (paper §4.3)
    kinds = [op.component for step in up.steps for op in step]
    first_kv = kinds.index("kv")
    assert all(k == "mlp" for k in kinds[:first_kv])
    # reversed traversal: last layer first
    first_step_layers = [op.layer for op in up.steps[0]]
    assert first_step_layers[0] == 7

    down = scale_down_schedule(8, layers_per_step=1)
    assert down.n_steps == 8  # layer-staggered
    for step in down.steps:
        layers = {op.layer for op in step}
        assert len(layers) == 1  # one layer per step


def test_overhead_small_like_fig11():
    """Fig. 11: Gyges keeps per-step overhead small and total cost far
    below the Seesaw-style baseline."""
    from repro.core.transform_engine import seesaw_cost
    cfg = get_config("qwen2.5-32b")
    plan = make_plan(cfg, 4, mode="page")
    link = LinkModel()
    kv = account_scale_up("header_centric", 4, 60, 8, 64,
                          cfg.resolved_head_dim, n_stages=8)
    sched = scale_up_schedule(cfg.num_layers, layers_per_step=1)
    total, per_step = schedule_cost(sched, cfg, plan, kv, link,
                                    method="padded", overlap=True)
    assert total < 0.1                      # well under one second
    assert total < 0.05 * seesaw_cost(cfg, plan, cfg.num_layers, link)


def test_engine_with_mixed_lengths_and_arrivals():
    cfg = get_config("gemma-2b").reduced()
    eng = Engine(cfg, max_batch=2, max_seq=96)
    reqs = [ServeRequest(list(range(1, 1 + n)), max_new_tokens=4)
            for n in (3, 17, 9, 30)]
    eng.submit(reqs[0])
    eng.submit(reqs[1])
    eng.step()
    eng.submit(reqs[2])
    eng.submit(reqs[3])
    eng.run_until_done(400)
    assert all(r.done for r in reqs)
    assert all(len(r.generated) == 4 for r in reqs)


def test_cluster_survives_burst_of_longs():
    cfg = get_config("qwen2.5-32b")
    c = Cluster(cfg, n_hosts=2, scheduler=GygesScheduler())
    reqs = [Request(i, float(i), 30_000, 50) for i in range(6)]
    reqs += [Request(100 + i, 0.5 * i, 800, 100) for i in range(60)]
    m = c.run(reqs, dt=0.25, drain=240.0)
    assert m["finished"] == m["total"]
    assert m["throughput_tps"] > 0
