"""Training substrate: loss decrease, WSD schedule, data determinism,
checkpoint round-trip."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.padding import make_plan
from repro.models import model as M
from repro.training import (DataConfig, SyntheticStream, adamw,
                            make_train_step, wsd)
from repro.training import checkpoint as ckpt


def test_loss_decreases(rng):
    cfg = get_config("llama3-8b").reduced()
    plan = make_plan(cfg, 2)
    params = M.init_params(rng, cfg, plan)
    opt_init, opt_update = adamw(wsd(3e-3, 5, 20, 25))
    st = opt_init(params)
    step = jax.jit(make_train_step(cfg, plan, opt_update))
    data = SyntheticStream(DataConfig(cfg.vocab_size, 32, 8, seed=0))
    losses = []
    for i in range(40):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, st, m = step(params, st, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3
    assert all(np.isfinite(l) for l in losses)


def test_wsd_schedule_shape():
    fn = wsd(1e-3, warmup=10, stable=20, decay=30, final_frac=0.1)
    lr = [float(fn(jnp.int32(s))) for s in (0, 5, 10, 25, 30, 60, 1000)]
    assert abs(lr[1] - 5e-4) < 1e-8      # mid-warmup
    assert abs(lr[2] - 1e-3) < 1e-8 and abs(lr[3] - 1e-3) < 1e-8  # stable
    assert abs(lr[4] - 1e-3) < 1e-8      # start of decay
    assert abs(lr[5] - 1e-4) / 1e-4 < 0.01
    assert lr[6] <= 1e-4 * 1.01


def test_data_deterministic_and_seekable():
    d1 = SyntheticStream(DataConfig(512, 16, 4, seed=3))
    d2 = SyntheticStream(DataConfig(512, 16, 4, seed=3))
    np.testing.assert_array_equal(d1.batch(7)["tokens"],
                                  d2.batch(7)["tokens"])
    assert not np.array_equal(d1.batch(7)["tokens"], d1.batch(8)["tokens"])


def test_checkpoint_roundtrip(tmp_path, rng):
    cfg = get_config("granite-moe-3b-a800m").reduced()
    plan = make_plan(cfg, 2)
    params = M.init_params(rng, cfg, plan)
    opt_init, _ = adamw(1e-3)
    st = opt_init(params)
    tree = {"params": params, "opt": st}
    ckpt.save(str(tmp_path / "ck"), tree, step=17)
    restored, step = ckpt.restore(str(tmp_path / "ck"))
    assert step == 17
    flat_a = jax.tree.leaves(tree)
    flat_b = jax.tree.leaves(restored)
    assert len(flat_a) == len(flat_b)
    for a, b in zip(flat_a, flat_b):
        aa, bb = np.asarray(a), np.asarray(b)
        assert aa.dtype == bb.dtype
        np.testing.assert_array_equal(aa.astype(np.float32),
                                      bb.astype(np.float32))
    # structure preserved (dict/list/tuple tags)
    assert isinstance(restored["opt"], tuple)
    assert isinstance(restored["params"]["blocks"], list)
