"""Multi-device integration tests (subprocess with 8 fake host devices —
the main pytest process must keep seeing 1 device, per the dry-run rule).

Covers the paper's headline mechanism end-to-end: a live TP1->TP4->TP1
transformation of a serving InstanceGroup with exact token continuity,
and the KV pool reshard data plane."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(body: str) -> str:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nERR:\n{out.stderr}"
    return out.stdout


@pytest.mark.slow
def test_live_transformation_token_continuity():
    out = run_py("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.core.instance import InstanceGroup

        # float32: token-exact continuity is the claim under test, and
        # bf16 cross-TP reduction order can flip near-tie argmaxes (see
        # test_transformation_faithful_mode_mlp_only's tolerance note)
        cfg = dataclasses.replace(get_config("llama3-8b").reduced(),
                                  dtype="float32")
        devs = jax.devices()[:4]
        kw = dict(batch_per_replica=1, max_seq=64, rng=jax.random.PRNGKey(3))
        inst = InstanceGroup(cfg, devs, **kw)
        ref = InstanceGroup(cfg, devs, **kw)
        B, S = inst.batch, 16
        toks = jax.random.randint(jax.random.PRNGKey(7), (B, S), 0,
                                  cfg.vocab_size)
        t0 = jnp.argmax(inst.prefill({"tokens": toks})[:, -1], -1)
        ref.prefill({"tokens": toks})
        t0 = t0.astype(jnp.int32)

        t, want = t0, []
        for i in range(6):
            lg = ref.decode(t, jnp.full((B,), S + i, jnp.int32))
            t = jnp.argmax(lg, -1).astype(jnp.int32)
            want.append(np.asarray(t))
        t, got = t0, []
        for i in range(6):
            if i == 2:
                inst.transform(4)
                assert inst.tp == 4
            if i == 4:
                inst.transform(1)
            lg = inst.decode(t, jnp.full((B,), S + i, jnp.int32))
            t = jnp.argmax(lg, -1).astype(jnp.int32)
            got.append(np.asarray(t))
        for a, b in zip(want, got):
            assert (a == b).all(), (a, b)
        assert inst.transform_count == 2
        print("CONTINUITY_OK")
    """)
    assert "CONTINUITY_OK" in out


@pytest.mark.slow
def test_pool_reshard_scale_up_preserves_content():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.core import kv_transform as KT

        W, NP, kvs, Pg, dh = 4, 6, 8, 8, 16
        mesh = Mesh(np.array(jax.devices()[:W]), ("tp",))
        rng = np.random.default_rng(0)
        host = jnp.asarray(rng.normal(size=(W, NP, kvs, 2, Pg, dh)),
                           jnp.float32)
        pools = jax.device_put(host, NamedSharding(mesh, P("tp")))
        merged = KT.reshard_scale_up(pools, mesh, "tp")
        assert merged.shape == (W * NP, kvs, 2, Pg, dh)
        # content preserved
        np.testing.assert_array_equal(
            np.asarray(merged), np.asarray(host).reshape(W * NP, kvs, 2,
                                                         Pg, dh))
        # sharded by heads now: each device holds kvs/W heads of ALL pages
        shard_shapes = {tuple(s.data.shape) for s in
                        merged.addressable_shards}
        assert shard_shapes == {(W * NP, kvs // W, 2, Pg, dh)}
        back = KT.reshard_scale_down(merged, W, mesh, "tp")
        np.testing.assert_array_equal(np.asarray(back), np.asarray(host))
        print("RESHARD_OK")
    """)
    assert "RESHARD_OK" in out


@pytest.mark.slow
def test_pool_reshard_roundtrip_identity_8dev():
    """Satellite invariant: reshard_scale_up -> reshard_scale_down is the
    identity on an 8-fake-device mesh, and the explicit kernel data plane
    (pallas gather/scatter + all_to_all) moves exactly the same bytes as
    the GSPMD reshard."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.core import kv_transform as KT

        W, NP, kvs, Pg, dh = 8, 4, 8, 8, 16
        mesh = Mesh(np.array(jax.devices()), ("tp",))
        rng = np.random.default_rng(0)
        host = jnp.asarray(rng.normal(size=(W, NP, kvs, 2, Pg, dh)),
                           jnp.float32)
        pools = jax.device_put(host, NamedSharding(mesh, P("tp")))
        merged = KT.reshard_scale_up(pools, mesh, "tp")
        back = KT.reshard_scale_down(merged, W, mesh, "tp")
        np.testing.assert_array_equal(np.asarray(back), np.asarray(host))

        # the kernel plane produces the identical global array, with the
        # identical shardings, without GSPMD planning the collective
        flat = jax.device_put(host.reshape(W * NP, kvs, 2, Pg, dh),
                              NamedSharding(mesh, P("tp")))
        up = KT.migrate_scale_up_sharded(flat, mesh, "tp", interpret=True)
        np.testing.assert_array_equal(np.asarray(up), np.asarray(merged))
        assert ({tuple(s.data.shape) for s in up.addressable_shards}
                == {tuple(s.data.shape) for s in merged.addressable_shards})
        down = KT.migrate_scale_down_sharded(up, mesh, "tp",
                                             interpret=True)
        np.testing.assert_array_equal(np.asarray(down), np.asarray(flat))
        print("ROUNDTRIP_OK")
    """)
    assert "ROUNDTRIP_OK" in out


@pytest.mark.slow
def test_instance_scheduled_transform_token_continuity():
    """The §4.3 schedule executed step-by-step (MLP-first up, staggered
    down, reversed traversal) with decode iterations BETWEEN steps keeps
    the token stream identical to a transformation-free reference."""
    out = run_py("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.core.instance import InstanceGroup

        cfg = dataclasses.replace(get_config("llama3-8b").reduced(),
                                  dtype="float32")
        devs = jax.devices()[:4]
        kw = dict(batch_per_replica=1, max_seq=64,
                  rng=jax.random.PRNGKey(3))
        inst = InstanceGroup(cfg, devs, **kw)
        ref = InstanceGroup(cfg, devs, **kw)
        B, S = inst.batch, 16
        toks = jax.random.randint(jax.random.PRNGKey(7), (B, S), 0,
                                  cfg.vocab_size)
        t0 = jnp.argmax(inst.prefill({"tokens": toks})[:, -1], -1)
        t0 = t0.astype(jnp.int32)
        ref.prefill({"tokens": toks})
        t, want = t0, []
        for i in range(10):
            lg = ref.decode(t, jnp.full((B,), S + i, jnp.int32))
            t = jnp.argmax(lg, -1).astype(jnp.int32)
            want.append(np.asarray(t))
        t, got, i = t0, [], 0
        def dec():
            global t, i
            lg = inst.decode(t, jnp.full((B,), S + i, jnp.int32))
            t = jnp.argmax(lg, -1).astype(jnp.int32)
            got.append(np.asarray(t)); i += 1
        dec(); dec()
        session = inst.begin_transform(4, layers_per_step=1)
        kv_kernel_steps = 0
        while not session.done:
            rep = session.step()
            kv_kernel_steps += int(rep.kernel_plane)
            dec()                       # decode BETWEEN schedule steps
        inst.finish_transform()
        assert inst.tp == 4
        assert kv_kernel_steps > 0      # pallas+all_to_all plane ran
        reports = inst.transform_scheduled(1, layers_per_step=1)
        assert inst.tp == 1 and len(reports) > 0
        while i < 10:
            dec()
        for a, b in zip(want, got):
            assert (a == b).all(), (a, b)
        assert inst.transform_count == 2
        print("SCHEDULED_OK")
    """)
    assert "SCHEDULED_OK" in out


@pytest.mark.slow
def test_engine_live_transform_mid_decode():
    """Acceptance: an Engine serving in-flight requests completes a TP
    1->2 transformation mid-decode; subsequent decode outputs are
    identical to an engine started at the target TP, and KV crosses the
    boundary bit-exactly."""
    out = run_py("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.core.padding import make_plan
        from repro.models import model as M
        from repro.serving.engine import Engine
        from repro.serving.request import ServeRequest

        cfg = dataclasses.replace(get_config("llama3-8b").reduced(),
                                  dtype="float32")
        devs = jax.devices()[:2]
        host_params = M.init_params(jax.random.PRNGKey(11), cfg,
                                    make_plan(cfg, 2, mode="page"))

        def mk():
            return Engine(cfg, params=host_params, max_batch=2,
                          max_seq=64, page_tokens=16, devices=devs)

        def reqs():
            return [ServeRequest(rid=i, prompt=list(range(5 + i, 21 + i)),
                                 max_new_tokens=24) for i in range(2)]

        # engine started AT the target TP serves the same requests
        b = mk()
        b.transform(2)
        while b.transforming: b.step()
        assert b.tp == 2
        rb = reqs()
        for r in rb: b.submit(r)
        b.run_until_done()
        want = [list(r.generated) for r in rb]

        # engine transforms 1->2 MID-DECODE with requests in flight
        a = mk()
        ra = reqs()
        for r in ra: a.submit(r)
        for _ in range(6): a.step()
        assert all(r.slot is not None for r in ra)
        n = a.transform(2)
        assert n > 0
        mid = 0
        while a.transforming:
            a.step(); mid += 1          # one schedule step + one decode
        assert a.tp == 2 and mid == n
        a.run_until_done()
        got = [list(r.generated) for r in ra]
        assert got == want, (got, want)
        kv_reports = [r for r in a.transform_reports
                      if any(o.component == "kv" for o in r.ops)]
        assert kv_reports and all(r.kernel_plane for r in kv_reports)

        # bit-exact KV across the boundary: migrate with no interleaved
        # decode and compare every cache byte
        c = mk()
        rc = reqs()
        for r in rc: c.submit(r)
        for _ in range(6): c.step()
        before = jax.tree.leaves(jax.device_get(c.caches))
        c.transform(2)
        s = c._session
        while not s.done:
            s.step()
        c._finish_transform()
        after = jax.tree.leaves(jax.device_get(c.caches))
        for x, y in zip(before, after):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        print("ENGINE_TRANSFORM_OK")
    """)
    assert "ENGINE_TRANSFORM_OK" in out


@pytest.mark.slow
def test_transform_streams_weights_per_decode_layer():
    """ISSUE-7 prong 2: a live transform streams each schedule step's
    transfers layer by layer, interleaved with the decode iteration's
    layer walk.  Every StepReport carries per-layer dispatch spans that
    exactly cover the step's ops, the final step ships the static
    params as their own span, and the session's transform_log record
    surfaces the overlap fraction."""
    out = run_py("""
        import dataclasses
        import jax
        from repro.configs import get_config
        from repro.core.padding import make_plan
        from repro.models import model as M
        from repro.serving.engine import Engine
        from repro.serving.request import ServeRequest

        cfg = dataclasses.replace(get_config("llama3-8b").reduced(),
                                  dtype="float32")
        devs = jax.devices()[:2]
        host_params = M.init_params(jax.random.PRNGKey(11), cfg,
                                    make_plan(cfg, 2, mode="page"))
        a = Engine(cfg, params=host_params, max_batch=2, max_seq=64,
                   page_tokens=16, devices=devs)
        reqs = [ServeRequest(rid=i, prompt=list(range(5 + i, 21 + i)),
                             max_new_tokens=24) for i in range(2)]
        for r in reqs: a.submit(r)
        for _ in range(6): a.step()
        assert all(r.slot is not None for r in reqs)
        n = a.transform(2)
        assert n > 1                  # the schedule really staged
        while a.transforming:
            a.step()                  # decode runs UNDER the transfers
        a.run_until_done()

        reps = a.transform_reports
        assert len(reps) == n
        for r in reps:
            assert r.layer_spans, r
            assert {s[0] for s in r.layer_spans if s[0] >= 0} == {
                o.layer for o in r.ops}
            for layer, comps, start_rel, dur in r.layer_spans:
                assert comps and start_rel >= 0.0 and dur >= 0.0
        # one span per layer GROUP: a layer's mlp+kv ops share a span
        for r in reps:
            layers = [s[0] for s in r.layer_spans]
            assert len(layers) == len(set(layers))
        # static params ride the FINAL step as their own span
        assert any(s[0] == -1 and s[1] == ("static",)
                   for s in reps[-1].layer_spans)
        assert not any(s[0] == -1 for r in reps[:-1]
                       for s in r.layer_spans)

        rec = a.transform_log[-1]
        assert 0.0 <= rec["overlap_frac"] <= 1.0, rec
        print("SPANS_OK")
    """)
    assert "SPANS_OK" in out


@pytest.mark.slow
def test_transformation_faithful_mode_mlp_only():
    """paper-faithful transform_attn_weights=False: attention weights stay
    replicated, transformation still exact."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.core.instance import InstanceGroup
        cfg = get_config("gemma-2b").reduced()
        devs = jax.devices()[:4]
        kw = dict(batch_per_replica=1, max_seq=64,
                  rng=jax.random.PRNGKey(5), transform_attn=False)
        inst = InstanceGroup(cfg, devs, **kw)
        ref = InstanceGroup(cfg, devs, **kw)
        B, S = inst.batch, 8
        toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                  cfg.vocab_size)
        t0 = jnp.argmax(inst.prefill({"tokens": toks})[:, -1], -1).astype(
            jnp.int32)
        ref.prefill({"tokens": toks})
        # different shardings change bf16 reduction order, so compare
        # LOGITS with tolerance (token-exact equality is only guaranteed
        # within one instance, which test 1 covers)
        t = t0
        ref_logits, fed = [], []
        for i in range(4):
            fed.append(t)
            lg = ref.decode(t, jnp.full((B,), S + i, jnp.int32))
            t = jnp.argmax(lg, -1).astype(jnp.int32)
            ref_logits.append(np.asarray(lg, np.float32))
        inst.transform(2)
        for i in range(4):  # teacher-forced with ref tokens
            lg = inst.decode(fed[i], jnp.full((B,), S + i, jnp.int32))
            got = np.asarray(lg, np.float32)
            scale = np.abs(ref_logits[i]).max() + 1e-9
            err = np.abs(got - ref_logits[i]).max() / scale
            assert err < 3e-2, f"step {i}: rel err {err}"
        print("FAITHFUL_OK")
    """)
    assert "FAITHFUL_OK" in out
