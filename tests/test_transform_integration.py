"""Multi-device integration tests (subprocess with 8 fake host devices —
the main pytest process must keep seeing 1 device, per the dry-run rule).

Covers the paper's headline mechanism end-to-end: a live TP1->TP4->TP1
transformation of a serving InstanceGroup with exact token continuity,
and the KV pool reshard data plane."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(body: str) -> str:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nERR:\n{out.stderr}"
    return out.stdout


@pytest.mark.slow
def test_live_transformation_token_continuity():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.core.instance import InstanceGroup

        cfg = get_config("llama3-8b").reduced()
        devs = jax.devices()[:4]
        kw = dict(batch_per_replica=1, max_seq=64, rng=jax.random.PRNGKey(3))
        inst = InstanceGroup(cfg, devs, **kw)
        ref = InstanceGroup(cfg, devs, **kw)
        B, S = inst.batch, 16
        toks = jax.random.randint(jax.random.PRNGKey(7), (B, S), 0,
                                  cfg.vocab_size)
        t0 = jnp.argmax(inst.prefill({"tokens": toks})[:, -1], -1)
        ref.prefill({"tokens": toks})
        t0 = t0.astype(jnp.int32)

        t, want = t0, []
        for i in range(6):
            lg = ref.decode(t, jnp.full((B,), S + i, jnp.int32))
            t = jnp.argmax(lg, -1).astype(jnp.int32)
            want.append(np.asarray(t))
        t, got = t0, []
        for i in range(6):
            if i == 2:
                inst.transform(4)
                assert inst.tp == 4
            if i == 4:
                inst.transform(1)
            lg = inst.decode(t, jnp.full((B,), S + i, jnp.int32))
            t = jnp.argmax(lg, -1).astype(jnp.int32)
            got.append(np.asarray(t))
        for a, b in zip(want, got):
            assert (a == b).all(), (a, b)
        assert inst.transform_count == 2
        print("CONTINUITY_OK")
    """)
    assert "CONTINUITY_OK" in out


@pytest.mark.slow
def test_pool_reshard_scale_up_preserves_content():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.core import kv_transform as KT

        W, NP, kvs, Pg, dh = 4, 6, 8, 8, 16
        mesh = Mesh(np.array(jax.devices()[:W]), ("tp",))
        rng = np.random.default_rng(0)
        host = jnp.asarray(rng.normal(size=(W, NP, kvs, 2, Pg, dh)),
                           jnp.float32)
        pools = jax.device_put(host, NamedSharding(mesh, P("tp")))
        merged = KT.reshard_scale_up(pools, mesh, "tp")
        assert merged.shape == (W * NP, kvs, 2, Pg, dh)
        # content preserved
        np.testing.assert_array_equal(
            np.asarray(merged), np.asarray(host).reshape(W * NP, kvs, 2,
                                                         Pg, dh))
        # sharded by heads now: each device holds kvs/W heads of ALL pages
        shard_shapes = {tuple(s.data.shape) for s in
                        merged.addressable_shards}
        assert shard_shapes == {(W * NP, kvs // W, 2, Pg, dh)}
        back = KT.reshard_scale_down(merged, W, mesh, "tp")
        np.testing.assert_array_equal(np.asarray(back), np.asarray(host))
        print("RESHARD_OK")
    """)
    assert "RESHARD_OK" in out


@pytest.mark.slow
def test_transformation_faithful_mode_mlp_only():
    """paper-faithful transform_attn_weights=False: attention weights stay
    replicated, transformation still exact."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.core.instance import InstanceGroup
        cfg = get_config("gemma-2b").reduced()
        devs = jax.devices()[:4]
        kw = dict(batch_per_replica=1, max_seq=64,
                  rng=jax.random.PRNGKey(5), transform_attn=False)
        inst = InstanceGroup(cfg, devs, **kw)
        ref = InstanceGroup(cfg, devs, **kw)
        B, S = inst.batch, 8
        toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                  cfg.vocab_size)
        t0 = jnp.argmax(inst.prefill({"tokens": toks})[:, -1], -1).astype(
            jnp.int32)
        ref.prefill({"tokens": toks})
        # different shardings change bf16 reduction order, so compare
        # LOGITS with tolerance (token-exact equality is only guaranteed
        # within one instance, which test 1 covers)
        t = t0
        ref_logits, fed = [], []
        for i in range(4):
            fed.append(t)
            lg = ref.decode(t, jnp.full((B,), S + i, jnp.int32))
            t = jnp.argmax(lg, -1).astype(jnp.int32)
            ref_logits.append(np.asarray(lg, np.float32))
        inst.transform(2)
        for i in range(4):  # teacher-forced with ref tokens
            lg = inst.decode(fed[i], jnp.full((B,), S + i, jnp.int32))
            got = np.asarray(lg, np.float32)
            scale = np.abs(ref_logits[i]).max() + 1e-9
            err = np.abs(got - ref_logits[i]).max() / scale
            assert err < 3e-2, f"step {i}: rel err {err}"
        print("FAITHFUL_OK")
    """)
    assert "FAITHFUL_OK" in out
