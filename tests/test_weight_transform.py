"""Weight transformation accounting + padded split mechanics (§4.2)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import weight_transform as WT
from repro.core.kv_transform import LinkModel
from repro.core.padding import make_plan


def test_padded_scale_up_is_zero_copy():
    """Page-aligned padding -> scale-up releases pages without copying a
    single byte (the paper's headline §4.2 property)."""
    cfg = get_config("llama3-8b")
    plan = make_plan(cfg, 4, mode="page")
    assert plan.page_aligned
    st = WT.account_scale_up(cfg, plan, 4, "padded")
    assert st.bytes_copied == 0
    assert st.bytes_transferred == 0
    assert st.page_ops > 0
    # swap path must copy the kept shard
    sw = WT.account_scale_up(cfg, plan, 4, "swap")
    assert sw.bytes_copied > 0
    link = LinkModel()
    assert st.time_s(link) < sw.time_s(link)


def test_scale_down_bytes_are_physics():
    """Scale-down must move (tp-1)/tp of the weights regardless of method
    — padding only removes the extra local copies."""
    cfg = get_config("llama3-8b")
    plan = make_plan(cfg, 4, mode="page")
    pad = WT.account_scale_down(cfg, plan, 4, "padded")
    swp = WT.account_scale_down(cfg, plan, 4, "swap")
    assert pad.bytes_transferred == swp.bytes_transferred > 0
    assert pad.bytes_copied == 0 and swp.bytes_copied > 0
    layer = WT.mlp_layer_bytes(cfg, plan, padded=True)
    assert pad.bytes_transferred == layer - layer // 4


def test_unaligned_model_falls_back_to_swap():
    cfg = get_config("granite-moe-3b-a800m")
    plan = make_plan(cfg, 4, mode="page")
    assert not plan.page_aligned
    st = WT.account_scale_up(cfg, plan, 4, "padded")
    assert st.bytes_copied > 0  # cannot be zero-copy without alignment


def test_pad_split_roundtrip():
    """Slicing each shard's real columns back out of the padded tensor
    recovers the original exactly."""
    rng = np.random.default_rng(0)
    d, ff, ffp, tp = 16, 24, 32, 4
    w = jnp.asarray(rng.normal(size=(d, ff)), jnp.float32)
    wp = WT.pad_columns_for_tp(w, ff, ffp, tp)
    shard, shard_p = ff // tp, ffp // tp
    rec = []
    for i in range(tp):
        rec.append(np.asarray(wp[:, i * shard_p:i * shard_p + shard]))
        # padding tail must be exactly zero
        tail = np.asarray(wp[:, i * shard_p + shard:(i + 1) * shard_p])
        assert (tail == 0).all()
    np.testing.assert_array_equal(np.concatenate(rec, 1), np.asarray(w))

    wr = jnp.asarray(rng.normal(size=(ff, d)), jnp.float32)
    wrp = WT.pad_rows_for_tp(wr, ff, ffp, tp)
    rec = [np.asarray(wrp[i * shard_p:i * shard_p + shard]) for i in
           range(tp)]
    np.testing.assert_array_equal(np.concatenate(rec, 0), np.asarray(wr))


def test_overlap_reduces_time():
    cfg = get_config("qwen2.5-32b")
    plan = make_plan(cfg, 4, mode="page")
    link = LinkModel()
    dn = WT.account_scale_down(cfg, plan, 4, "padded")
    assert dn.time_s(link, overlap=True) < dn.time_s(link) * 0.5


def test_moe_layer_bytes_include_experts():
    g = get_config("granite-moe-3b-a800m")
    plan = make_plan(g, 4, mode="page")
    b = WT.mlp_layer_bytes(g, plan, padded=False)
    expected = 3 * g.d_model * g.d_ff * 2 * g.moe.num_experts \
        + g.d_model * g.moe.num_experts * 2
    assert b == expected
