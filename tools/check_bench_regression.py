#!/usr/bin/env python
"""Gate the CI perf trajectory: compare a candidate ``BENCH_*.json``
(from ``benchmarks/run.py --trajectory``) against the committed
baseline and exit non-zero on any gated-column regression beyond the
threshold.

Rules (direction-aware, taken from the BASELINE's ``gates`` map so a
candidate cannot un-gate a column by dropping it):

* every baseline scenario must exist in the candidate, and every gated
  column must be present — a missing scenario/column is a FAILURE, not
  a skip (renames go through a schema_version bump);
* relative change is measured against the baseline value; ``higher``
  columns fail when the candidate is > threshold BELOW baseline,
  ``lower`` columns when > threshold ABOVE;
* NaN on either side skips the column (the untimed paths report NaN
  goodput by contract) and a near-zero baseline skips the ratio (noted
  in the output, never divided by).

Usage:
    python tools/check_bench_regression.py \
        benchmarks/BENCH_baseline.json BENCH_2026-08-08.json
"""
from __future__ import annotations

import argparse
import json
import math
import sys


def compare(base: dict, cand: dict, threshold: float) -> list[str]:
    failures: list[str] = []
    if base.get("schema_version") != cand.get("schema_version"):
        return [f"schema_version mismatch: baseline "
                f"{base.get('schema_version')} vs candidate "
                f"{cand.get('schema_version')} (regenerate the baseline)"]
    gates = base.get("gates", {})
    for scen, cols in sorted(base.get("scenarios", {}).items()):
        c_cols = cand.get("scenarios", {}).get(scen)
        if c_cols is None:
            failures.append(f"{scen}: scenario missing from candidate")
            continue
        for col, bv in sorted(cols.items()):
            direction = gates.get(col)
            if direction is None:
                continue                      # informational column
            cv = c_cols.get(col)
            if cv is None:
                failures.append(f"{scen}.{col}: column missing "
                                f"from candidate")
                continue
            bv, cv = float(bv), float(cv)
            if math.isnan(bv) or math.isnan(cv):
                print(f"  skip {scen}.{col}: NaN "
                      f"(baseline={bv}, candidate={cv})")
                continue
            if abs(bv) < 1e-12:
                print(f"  skip {scen}.{col}: near-zero baseline {bv}")
                continue
            rel = (cv - bv) / abs(bv)
            worse = (-rel if direction == "higher" else rel)
            mark = "REGRESSION" if worse > threshold else "ok"
            print(f"  {mark:>10} {scen}.{col}: {bv:.4g} -> {cv:.4g} "
                  f"({rel:+.1%}, gate: {direction} is better)")
            if worse > threshold:
                failures.append(
                    f"{scen}.{col}: {bv:.4g} -> {cv:.4g} ({rel:+.1%}) "
                    f"exceeds the {threshold:.0%} {direction}-is-better "
                    f"gate")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="committed BENCH_baseline.json")
    ap.add_argument("candidate", help="freshly emitted BENCH_<date>.json")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="max tolerated relative regression in a gated "
                         "column (default 0.15)")
    args = ap.parse_args()
    with open(args.baseline) as f:
        base = json.load(f)
    with open(args.candidate) as f:
        cand = json.load(f)
    print(f"baseline {args.baseline} vs candidate {args.candidate} "
          f"(threshold {args.threshold:.0%})")
    failures = compare(base, cand, args.threshold)
    if failures:
        print(f"\n{len(failures)} gated regression(s):")
        for f_ in failures:
            print(f"  FAIL {f_}")
        return 1
    print("\nall gated columns within threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
