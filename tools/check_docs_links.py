#!/usr/bin/env python3
"""Dead-link check for the markdown docs (CI `docs` job).

Scans ``docs/**/*.md`` plus the top-level ``*.md`` files for inline
markdown links ``[text](target)`` and fails if a *relative* target does
not exist on disk (resolved against the linking file's directory).
External links (``http(s)://``, ``mailto:``) and pure in-page anchors
(``#...``) are skipped; a ``path#anchor`` target is checked for the
path only.  Stdlib-only so it runs anywhere:

    python tools/check_docs_links.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
REPO = Path(__file__).resolve().parent.parent


def md_files() -> list[Path]:
    files = sorted(REPO.glob("*.md"))
    files += sorted((REPO / "docs").rglob("*.md"))
    return files


def check(path: Path) -> list[str]:
    errors = []
    for n, line in enumerate(path.read_text().splitlines(), 1):
        for target in LINK.findall(line):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if not (path.parent / rel).exists():
                errors.append(f"{path.relative_to(REPO)}:{n}: "
                              f"dead link -> {target}")
    return errors


def main() -> int:
    files = md_files()
    errors = [e for f in files for e in check(f)]
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(files)} markdown files: "
          f"{'FAIL' if errors else 'ok'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
